"""Figure 8 analogue: row-wise CPU baseline scaling with thread count.

Reproduces the paper's scaling-collapse result: per-stage wall time for
the row-partitioned pipeline at 1..16 threads, with the stateful
sub-dictionary merge modeled faithfully. Threads are emulated (each
thread's work timed, wall time = max over threads + serial merge), so
numbers reflect the algorithmic scaling behaviour the paper plots, not
the host's actual core count.

``--sharded`` runs the counterpoint: the data-parallel
``ShardedPiperPipeline`` (local GenVocab state + one merge tree — no
per-row synchronization) at shard counts {1, 2, 4, 8} on forced host
devices, reporting total and per-shard throughput:

    PYTHONPATH=src python benchmarks/fig8_cpu_scaling.py --sharded

(the script forces ``--xla_force_host_platform_device_count=8`` itself
when jax has not initialized yet).

Output columns: config,threads,stage → seconds.
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):  # direct script invocation
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    sys.path.insert(0, _ROOT)


from benchmarks.common import emit
from repro.core import baseline, schema as schema_lib
from repro.data import synth

SHARD_COUNTS = (1, 2, 4, 8)

ROWS = 6_000
THREADS = (1, 2, 4, 8, 16)


def run_config(name: str, vocab_range: int, binary: bool) -> None:
    schema = schema_lib.TableSchema(vocab_range=vocab_range)
    cfg = synth.SynthConfig(schema=schema, rows=ROWS, seed=0)
    buf, table = synth.make_dataset(cfg)

    for n_threads in THREADS:
        t0 = time.perf_counter()
        if binary:
            rows = table["label"].shape[0]
            slices = [
                slice((rows * t) // n_threads, (rows * (t + 1)) // n_threads)
                for t in range(n_threads)
            ]
            parts = [
                {k: table[k][s] for k in ("label", "dense", "sparse")}
                for s in slices
            ]
            t_sif = time.perf_counter() - t0
            t_decode_max = 0.0
        else:
            subs = baseline.split_input_file(buf, n_threads)
            t_sif = time.perf_counter() - t0
            decode_times, parts = [], []
            for s in subs:
                td = time.perf_counter()
                parts.append(baseline.decode_rows_serial(s, schema))
                decode_times.append(time.perf_counter() - td)
            t_decode_max = max(decode_times)

        gv_times, subdicts = [], []
        for p in parts:
            tg = time.perf_counter()
            modded = baseline.positive_modulus(p["sparse"], schema.vocab_range)
            subdicts.append(baseline.generate_vocab_thread(modded, schema))
            gv_times.append(time.perf_counter() - tg)
        t_gv_max = max(gv_times)

        tm = time.perf_counter()
        vocab = baseline.merge_sub_dictionaries(subdicts, schema)  # SERIAL
        t_merge = time.perf_counter() - tm

        av_times, outs = [], []
        for p in parts:
            ta = time.perf_counter()
            outs.append(baseline.apply_vocab(p, vocab, schema))
            av_times.append(time.perf_counter() - ta)
        t_av_max = max(av_times)

        tc = time.perf_counter()
        baseline.concatenate(outs)
        t_cfr = time.perf_counter() - tc

        wall = t_sif + t_decode_max + t_gv_max + t_merge + t_av_max + t_cfr
        emit(
            f"fig8/{name}/threads{n_threads}",
            wall,
            f"rows_per_s={ROWS / wall:.0f};sif={t_sif:.3f};decode={t_decode_max:.3f};"
            f"gv={t_gv_max:.3f};merge={t_merge:.3f};av={t_av_max:.3f};cfr={t_cfr:.3f}",
        )


def run_sharded() -> None:
    """Data-parallel engine throughput sweep over SHARD_COUNTS.

    Every shard count processes the SAME dataset (strong scaling): total
    rows/s should grow with shards because loop ① is local per shard and
    the only cross-shard work is the final merge tree.
    """
    # Force 8 host devices if jax hasn't initialized its backend yet
    # (XLA_FLAGS is read lazily at first backend use, not at import).
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8"
        ).strip()

    import jax
    import jax.numpy as jnp

    from repro.core import pipeline as pipeline_lib
    from repro.core import sharded_pipeline as sp_lib
    from repro.data import loader
    from repro.distributed.sharding import put_shard_feed
    from repro.launch.mesh import make_data_mesh
    from benchmarks.common import time_fn

    n_devices = len(jax.devices())
    cfg = synth.SynthConfig(rows=ROWS, seed=0)
    buf, _ = synth.make_dataset(cfg)
    chunk_bytes = 1 << 14

    for n_shards in SHARD_COUNTS:
        if n_shards > n_devices:
            emit(
                f"fig8/sharded/shards{n_shards}",
                0.0,
                f"SKIPPED=only_{n_devices}_devices;set_XLA_FLAGS=--xla_force_host_platform_device_count=8",
            )
            continue
        mesh = make_data_mesh(n_shards)
        pc = pipeline_lib.PipelineConfig(
            schema=cfg.schema, chunk_bytes=chunk_bytes, max_rows_per_chunk=512
        )
        feed = loader.TabularChunkFeed(buf, chunk_bytes, n_shards)
        stacks, offsets = feed.shard_stacks()
        chunks, offs = put_shard_feed(
            jnp.asarray(stacks), jnp.asarray(offsets), mesh
        )
        eng = sp_lib.ShardedPiperPipeline(pc, mesh)
        sec = time_fn(eng.run_scan, chunks, offs)
        emit(
            f"fig8/sharded/shards{n_shards}",
            sec,
            f"rows_per_s={ROWS / sec:.0f};rows_per_s_per_shard={ROWS / sec / n_shards:.0f};"
            f"steps_per_shard={feed.n_steps}",
        )


def main(sharded: bool = False) -> None:
    if sharded:
        run_sharded()
        return
    run_config("vocab5k_utf8", 5_000, binary=False)
    run_config("vocab5k_binary", 5_000, binary=True)
    run_config("vocab1m_utf8", 1_000_000, binary=False)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--sharded",
        action="store_true",
        help="run the data-parallel ShardedPiperPipeline shard sweep "
        "instead of the CPU-baseline thread sweep",
    )
    args = ap.parse_args()
    main(sharded=args.sharded)
