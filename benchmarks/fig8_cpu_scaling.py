"""Figure 8 analogue: row-wise CPU baseline scaling with thread count.

Reproduces the paper's scaling-collapse result: per-stage wall time for
the row-partitioned pipeline at 1..16 threads, with the stateful
sub-dictionary merge modeled faithfully. Threads are emulated (each
thread's work timed, wall time = max over threads + serial merge), so
numbers reflect the algorithmic scaling behaviour the paper plots, not
the host's actual core count.

Output columns: config,threads,stage → seconds.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import baseline, schema as schema_lib
from repro.data import synth
from benchmarks.common import emit

ROWS = 6_000
THREADS = (1, 2, 4, 8, 16)


def run_config(name: str, vocab_range: int, binary: bool) -> None:
    schema = schema_lib.TableSchema(vocab_range=vocab_range)
    cfg = synth.SynthConfig(schema=schema, rows=ROWS, seed=0)
    buf, table = synth.make_dataset(cfg)

    for n_threads in THREADS:
        t0 = time.perf_counter()
        if binary:
            rows = table["label"].shape[0]
            slices = [
                slice((rows * t) // n_threads, (rows * (t + 1)) // n_threads)
                for t in range(n_threads)
            ]
            parts = [
                {k: table[k][s] for k in ("label", "dense", "sparse")}
                for s in slices
            ]
            t_sif = time.perf_counter() - t0
            t_decode_max = 0.0
        else:
            subs = baseline.split_input_file(buf, n_threads)
            t_sif = time.perf_counter() - t0
            decode_times, parts = [], []
            for s in subs:
                td = time.perf_counter()
                parts.append(baseline.decode_rows_serial(s, schema))
                decode_times.append(time.perf_counter() - td)
            t_decode_max = max(decode_times)

        gv_times, subdicts = [], []
        for p in parts:
            tg = time.perf_counter()
            modded = baseline.positive_modulus(p["sparse"], schema.vocab_range)
            subdicts.append(baseline.generate_vocab_thread(modded, schema))
            gv_times.append(time.perf_counter() - tg)
        t_gv_max = max(gv_times)

        tm = time.perf_counter()
        vocab = baseline.merge_sub_dictionaries(subdicts, schema)  # SERIAL
        t_merge = time.perf_counter() - tm

        av_times, outs = [], []
        for p in parts:
            ta = time.perf_counter()
            outs.append(baseline.apply_vocab(p, vocab, schema))
            av_times.append(time.perf_counter() - ta)
        t_av_max = max(av_times)

        tc = time.perf_counter()
        baseline.concatenate(outs)
        t_cfr = time.perf_counter() - tc

        wall = t_sif + t_decode_max + t_gv_max + t_merge + t_av_max + t_cfr
        emit(
            f"fig8/{name}/threads{n_threads}",
            wall,
            f"rows_per_s={ROWS / wall:.0f};sif={t_sif:.3f};decode={t_decode_max:.3f};"
            f"gv={t_gv_max:.3f};merge={t_merge:.3f};av={t_av_max:.3f};cfr={t_cfr:.3f}",
        )


def main() -> None:
    run_config("vocab5k_utf8", 5_000, binary=False)
    run_config("vocab5k_binary", 5_000, binary=True)
    run_config("vocab1m_utf8", 1_000_000, binary=False)


if __name__ == "__main__":
    main()
