"""Quickstart: the paper's pipeline in ~40 lines.

Raw UTF-8 Criteo-format rows → PIPER two-loop preprocessing
(Decode → Modulus → GenVocab → ApplyVocab ∥ Neg2Zero → Logarithm) →
vocabulary-encoded features, verified against the row-wise CPU oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import baseline, pipeline as P
from repro.data import synth

# 1. synthesize a Criteo-format dataset (1 label + 13 dense + 26 sparse)
cfg = synth.SynthConfig(rows=2_000, seed=0)
buf, _ = synth.make_dataset(cfg)
print(f"dataset: {cfg.rows} rows, {buf.size/1e6:.2f} MB UTF-8")

# 2. the PIPER engine: loop ① builds the vocabulary, loop ② applies it —
#    streaming over row-framed chunks, state carried between chunks
pipe = P.PiperPipeline(
    P.PipelineConfig(schema=cfg.schema, chunk_bytes=1 << 16, max_rows_per_chunk=1024)
)
chunks = lambda: synth.chunk_stream(buf, 1 << 16)

vocab = pipe.build_vocab_stream(chunks())
print(f"loop ① done: vocab sizes per column, e.g. {np.asarray(vocab.sizes[:6])}")

rows = 0
outs = []
for out in pipe.transform_stream(vocab, chunks()):
    v = np.asarray(out.valid)
    outs.append((np.asarray(out.sparse)[v], np.asarray(out.dense)[v]))
    rows += int(v.sum())
print(f"loop ② done: {rows} rows transformed")

# 3. verify bit-exact against the paper's row-wise CPU pipeline
oracle = baseline.run_pipeline(buf, cfg.schema, n_threads=4)
sparse = np.concatenate([s for s, _ in outs])
dense = np.concatenate([d for _, d in outs])
np.testing.assert_array_equal(sparse, oracle["sparse"])
np.testing.assert_allclose(dense, oracle["dense"], rtol=1e-6)
print("verified: columnar engine == row-wise CPU oracle (bit-exact ordinals)")
print("sample row 0 sparse ordinals:", sparse[0][:8], "dense:", dense[0][:4])
