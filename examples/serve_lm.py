"""Batched LM serving with continuous batching (smoke-scale).

    PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm as lm_lib
from repro.serve import engine as engine_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b", choices=configs.ARCH_IDS)
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    if cfg.family == "audio":
        raise SystemExit("pick a decoder-only arch")
    model = lm_lib.LM(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = engine_lib.ServeEngine(model, params, batch_slots=4, cache_len=48)

    rng = np.random.default_rng(1)
    reqs = [
        engine_lib.Request(
            prompt=rng.integers(0, cfg.vocab_size, 6).tolist(), max_new_tokens=12
        )
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in reqs)
    print(f"{args.arch}: {len(reqs)} requests / {toks} tokens in {dt:.2f}s")
    print("first generations:", [r.generated[:6] for r in reqs[:3]])


if __name__ == "__main__":
    main()
