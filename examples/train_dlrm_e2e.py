"""End-to-end driver: PIPER preprocessing → DLRM training (the paper's
Figure 2 system, in one program).

Streams a synthetic Criteo dataset through the two-loop engine, then
trains the DLRM CTR model on the preprocessed output for a few hundred
steps with the fault-tolerant trainer (async checkpoints included).

    PYTHONPATH=src python examples/train_dlrm_e2e.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import piper_dlrm
from repro.core import pipeline as P
from repro.data import synth
from repro.models import dlrm
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--rows", type=int, default=8_192)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=5_000)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dlrm_ckpt")
    args = ap.parse_args()

    # ---- preprocessing (the paper's contribution) -------------------- #
    import dataclasses

    from repro.core import schema as schema_lib

    schema = dataclasses.replace(schema_lib.CRITEO, vocab_range=args.vocab)
    scfg = synth.SynthConfig(schema=schema, rows=args.rows, seed=0)
    t0 = time.perf_counter()
    buf, _ = synth.make_dataset(scfg)
    pipe = P.PiperPipeline(
        P.PipelineConfig(schema=schema, chunk_bytes=1 << 17, max_rows_per_chunk=2048)
    )
    label, dense, sparse = [], [], []
    for out in pipe.run_stream(lambda: synth.chunk_stream(buf, 1 << 17)):
        v = np.asarray(out.valid)
        label.append(np.asarray(out.label)[v])
        dense.append(np.asarray(out.dense)[v])
        sparse.append(np.asarray(out.sparse)[v])
    data = {
        "label": np.concatenate(label),
        "dense": np.concatenate(dense),
        "sparse": np.concatenate(sparse),
    }
    print(f"PIPER preprocessing: {args.rows} rows in {time.perf_counter()-t0:.2f}s")

    # ---- DLRM training ------------------------------------------------ #
    mcfg = dlrm.DLRMConfig(vocab_range=args.vocab, embed_dim=16)
    params = dlrm.init(jax.random.PRNGKey(0), mcfg)
    opt_state = opt_lib.adamw_init(params)
    ocfg = opt_lib.AdamWConfig(
        schedule=opt_lib.cosine_schedule(2e-3, 20, args.steps), weight_decay=0.0
    )
    ckpt = ckpt_lib.AsyncCheckpointer(args.ckpt_dir, keep=2)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(dlrm.loss)(params, batch)
        params, opt_state, _ = opt_lib.adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, loss

    n = data["label"].shape[0]
    losses = []
    t0 = time.perf_counter()
    for i in range(args.steps):
        idx = np.random.default_rng(i).integers(0, n, args.batch)
        batch = {k: jnp.asarray(v[idx]) for k, v in data.items()}
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
        if (i + 1) % 100 == 0:
            ckpt.save_async(i + 1, {"params": params, "opt": opt_state})
            print(f"step {i+1}: loss={np.mean(losses[-50:]):.4f}")
    ckpt.wait()
    dt = time.perf_counter() - t0
    print(
        f"trained {args.steps} steps in {dt:.1f}s "
        f"({args.steps*args.batch/dt:.0f} rows/s); "
        f"loss {np.mean(losses[:20]):.4f} → {np.mean(losses[-20:]):.4f}"
    )
    assert np.mean(losses[-20:]) < np.mean(losses[:20])
    print(f"checkpoints at {args.ckpt_dir}: steps {ckpt_lib.list_steps(args.ckpt_dir)}")


if __name__ == "__main__":
    main()
