"""End-to-end driver: PIPER preprocessing → DLRM training (the paper's
Figure 2 system, in one program) — streamed and overlapped.

Training pulls its batches straight from the
:class:`~repro.stream.StreamingPreprocessService` through the
:class:`~repro.train.input_pipeline.TrainInputPipeline` bridge: raw
utf8 payloads are preprocessed on the fly, assembled into fixed-shape
batches, staged onto the device while the donated train step runs, and
cached content-addressed (:class:`~repro.data.chunk_cache.ChunkCache`)
so epochs ≥ 2 skip preprocessing entirely. Nothing is materialized up
front, and the hot path has no blocking host sync (the loss scalar is
read one step lagged).

At exit the driver prints the e2e stall split (input_wait vs
train_step), the service's own stall buckets, and the cache counters.

    PYTHONPATH=src python examples/train_dlrm_e2e.py [--steps 300]
        [--no-overlap] [--cache-mb 64] [--prefetch-depth 2]
        [--trace out.json]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import obs
from repro.core import pipeline as P
from repro.core import schema as schema_lib
from repro.data import chunk_cache as chunk_cache_lib
from repro.data import synth
from repro.models import dlrm
from repro.stream import StreamingPreprocessService
from repro.train import checkpoint as ckpt_lib
from repro.train import input_pipeline as input_lib
from repro.train import optimizer as opt_lib
from repro.train import steps as steps_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--rows", type=int, default=8_192)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=5_000)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dlrm_ckpt")
    ap.add_argument(
        "--no-overlap",
        action="store_true",
        help="stage batches synchronously inside next() (the stall baseline)",
    )
    ap.add_argument("--prefetch-depth", type=int, default=2)
    ap.add_argument(
        "--cache-mb",
        type=int,
        default=64,
        help="chunk-cache capacity in MiB (0 disables the cache)",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="OUT.json",
        help="export a Perfetto trace of the run plus a metrics snapshot "
        "(OUT.metrics.json) — the PR 7 observability machinery",
    )
    args = ap.parse_args()

    if args.trace:
        obs.enable()

    # ---- preprocessing service (the paper's contribution) ------------ #
    schema = dataclasses.replace(schema_lib.CRITEO, vocab_range=args.vocab)
    scfg = synth.SynthConfig(schema=schema, rows=args.rows, seed=0)
    t0 = time.perf_counter()
    buf, table = synth.make_dataset(scfg)
    payload_rows = min(args.batch, args.rows)
    config = P.PipelineConfig(
        schema=schema,
        chunk_bytes=1 << 17,
        max_rows_per_chunk=payload_rows,
    )
    pipe = P.PiperPipeline(config)
    state = pipe.build_state_stream(synth.chunk_stream(buf, 1 << 17))
    n_payloads = args.rows // payload_rows
    payloads = list(
        synth.request_payloads(buf, table, [payload_rows] * n_payloads)
    )
    cache = None
    if args.cache_mb > 0:
        cache = chunk_cache_lib.ChunkCache(capacity_bytes=args.cache_mb << 20)
    service = StreamingPreprocessService(
        config, state, bucket_rows=(payload_rows,), cache=cache
    ).start()
    print(
        f"PIPER loop-1 vocab over {args.rows} rows in "
        f"{time.perf_counter()-t0:.2f}s; streaming loop-2 from here on"
        f" (cache={'off' if cache is None else f'{args.cache_mb}MiB'})"
    )

    # ---- DLRM training, fed by the overlapped input bridge ----------- #
    # bottom_mlp must end at embed_dim (the dense vector joins the
    # per-table embeddings in the pairwise interaction)
    mcfg = dlrm.DLRMConfig(
        vocab_range=args.vocab,
        embed_dim=16,
        bottom_mlp=(128, 64, 16),
        top_mlp=(128, 64, 1),
    )
    params = dlrm.init(jax.random.PRNGKey(0), mcfg)
    opt_state = opt_lib.adamw_init(params)
    ocfg = opt_lib.AdamWConfig(
        schedule=opt_lib.cosine_schedule(2e-3, 20, args.steps), weight_decay=0.0
    )
    ckpt = ckpt_lib.AsyncCheckpointer(args.ckpt_dir, keep=2)
    step = jax.jit(
        steps_lib.make_tabular_train_step(dlrm.loss, ocfg), donate_argnums=(0, 1)
    )

    pipe_in = input_lib.TrainInputPipeline(
        service,
        lambda: iter(payloads),
        batch_rows=args.batch,
        n_steps=args.steps,
        overlap=not args.no_overlap,
        prefetch_depth=args.prefetch_depth,
    )

    losses: list[float] = []
    pending = None  # one-step-lagged loss sync: no blocking read on the
    # hot path — step i's scalar is resolved while step i+1 computes
    i = 0
    t0 = time.perf_counter()
    try:
        for batch in pipe_in:
            params, opt_state, metrics = step(params, opt_state, batch)
            if pending is not None:
                losses.append(float(pending["loss"]))
            pending = metrics
            i += 1
            if i % 100 == 0:
                losses.append(float(pending["loss"]))  # drain before save
                pending = None
                ckpt.save_async(i, {"params": params, "opt": opt_state})
                print(f"step {i}: loss={np.mean(losses[-50:]):.4f}")
        if pending is not None:
            losses.append(float(pending["loss"]))
        jax.block_until_ready(params)
    finally:
        service.stop()
    ckpt.wait()
    dt = time.perf_counter() - t0

    # ---- exit reports ------------------------------------------------ #
    print(
        f"trained {args.steps} steps in {dt:.1f}s "
        f"({args.steps*args.batch/dt:.0f} rows/s); "
        f"loss {np.mean(losses[:20]):.4f} → {np.mean(losses[-20:]):.4f}"
    )
    e2e = pipe_in.stall_report()
    print(
        f"e2e stall split: input_wait={e2e['fractions']['input_wait']:.1%} "
        f"train_step={e2e['fractions']['train_step']:.1%} "
        f"(attributed {e2e['attributed_s']:.2f}s of {e2e['wall_s']:.2f}s wall)"
    )
    svc_stall = service.stall_report()
    print(f"service stall buckets: {svc_stall['fractions']}")
    if cache is not None:
        st = cache.stats()
        print(
            f"chunk cache: {st['hits_total']} hits / {st['misses_total']} "
            f"misses ({st['items']} resident, {st['mem_bytes']/2**20:.1f} MiB)"
        )
    if args.trace:
        obs.tracer().export(args.trace)
        mpath = args.trace.replace(".json", "") + ".metrics.json"
        pipe_in.registry.export_jsonl(mpath)
        print(f"wrote {args.trace} + {mpath}")
    assert np.mean(losses[-20:]) < np.mean(losses[:20])
    print(f"checkpoints at {args.ckpt_dir}: steps {ckpt_lib.list_steps(args.ckpt_dir)}")


if __name__ == "__main__":
    main()
