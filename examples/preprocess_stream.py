"""Network-attached streaming preprocessing (paper §3.4.2).

Simulates the disaggregated deployment: the dataset is produced in
row-framed packets by a generator ("the network"), never materialized in
full; the engine streams both loops with only the per-column vocabulary
state held between chunks — datasets larger than (device) memory.

    PYTHONPATH=src python examples/preprocess_stream.py [--mb 64]
"""

import argparse
import time

import numpy as np

from repro.core import pipeline as P, schema as schema_lib
from repro.data import synth


def packet_stream(total_rows: int, rows_per_packet: int, chunk_bytes: int, seed=0):
    """Generator of row-framed byte packets (fresh each epoch/loop)."""
    done = 0
    shard = 0
    while done < total_rows:
        n = min(rows_per_packet, total_rows - done)
        cfg = synth.SynthConfig(rows=n, seed=(seed, shard).__hash__() & 0x7FFFFFFF)
        buf, _ = synth.make_dataset(cfg)
        yield from synth.chunk_stream(buf, chunk_bytes)
        done += n
        shard += 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=30_000)
    ap.add_argument("--chunk-kb", type=int, default=256)
    args = ap.parse_args()

    schema = schema_lib.CRITEO
    chunk_bytes = args.chunk_kb << 10
    pipe = P.PiperPipeline(
        P.PipelineConfig(schema=schema, chunk_bytes=chunk_bytes, max_rows_per_chunk=4096)
    )
    stream = lambda: packet_stream(args.rows, 5_000, chunk_bytes)

    t0 = time.perf_counter()
    vocab = pipe.build_vocab_stream(stream())
    t1 = time.perf_counter()
    rows = bytes_seen = 0
    for out in pipe.transform_stream(vocab, stream()):
        rows += int(np.asarray(out.valid).sum())
        bytes_seen += chunk_bytes
    t2 = time.perf_counter()

    print(f"loop ① (GenVocab): {t1-t0:.2f}s — vocab sizes {np.asarray(vocab.sizes[:5])}...")
    print(
        f"loop ② (ApplyVocab): {t2-t1:.2f}s — {rows} rows, "
        f"{bytes_seen/1e6:.1f} MB streamed, state footprint = "
        f"{vocab.table.size*4/1e6:.1f} MB (constant, independent of dataset size)"
    )
    print(f"throughput: {rows/(t2-t0):.0f} rows/s end-to-end on host CPU")


if __name__ == "__main__":
    main()
